//! Fused multi-tensor stepping vs per-tensor stepping — the regime real
//! models live in (dozens of LayerNorm / bias / projection tensors per
//! block) and the one the phased fused engine targets: per-tensor dispatch
//! amortizes to one pool batch per phase per training step, and
//! inter-tensor parallelism covers tensors smaller than one quantization
//! block.
//!
//! Workloads:
//! * `adam_many_small` — many equal small Adam tensors (block-local,
//!   single-phase plans);
//! * `reduction_mix` — a realistic embedding/projection/bias tensor-count
//!   mix stepped by the reduction-bearing optimizers (LAMB, Adafactor,
//!   factored SM3), whose two-/three-phase plans used to fall back to
//!   caller-side whole-tensor execution;
//! * `streaming_overlap` — gradients *produced serially* on the main
//!   thread (a stand-in for PJRT round-trips / runtime gradient
//!   production): `produce-then-fused` materializes every gradient before
//!   one fused step (the pool idles during production), `streaming`
//!   pushes each tensor into a `StreamingStep` the moment its gradient
//!   exists, so the pool updates tensor i while the main thread produces
//!   gradient i+1;
//! * `q4_width_sweep` — the same fused Adam workload at 32/8/4-bit state,
//!   bytes/element vs step time;
//! * `simd_sweep` — the fused Adam step per code width and format with
//!   lane-chunked kernels vs the bit-identical forced-scalar oracle
//!   (`--require-simd-speedup <x>` turns the recorded lane speedup into a
//!   CI gate);
//! * `stability_stress` — the fused Adam fleet with the stability phases
//!   on (percentile clip, max_unorm, skip_zeros) vs the plain baseline,
//!   under periodic gradient spikes; records drained clip-event counts so
//!   CI can verify the phases engaged, not just that they were cheap;
//! * `shard_sweep` — the same 8-bit Adam fleet partitioned across 1/2/4/8
//!   ZeRO-style shards (greedy bytes-balanced placement, one streaming
//!   batch per shard); records the max per-shard state bytes alongside
//!   step time — placement is bit-identical, so the footprint/step-time
//!   pair is the whole story;
//! * `adaptive_precision` — static 8-bit Adam vs the adaptive controller
//!   starting at 4-bit with a periodic gradient spike on one tensor: the
//!   controller promotes only the spiking tensor, so the adaptive peak
//!   state footprint stays strictly below static-8 while the spiking
//!   tensor still gets its wider state (transition count and peak bytes
//!   land in the JSON; CI greps for them).
//!
//! The first two workloads also run a `streaming` variant: admission per
//! tensor costs more dispatch than the fused one-batch-per-phase, which is
//! the price streaming pays when there is nothing to overlap.
//!
//! Emits machine-readable results to `BENCH_fused_step.json` (repo root)
//! so the perf trajectory is tracked across PRs.
//!
//! Run: `cargo bench --bench fused_step [-- --tensors 48 --n 4096
//!       --budget-ms 1200 --out BENCH_fused_step.json]`

use std::time::Duration;

use bitopt8::optim::{
    assign_greedy, build,
    engine::{fused_update, streaming_update, StreamingStep},
    sharded_update, take_clip_events, take_unorm_clips, Bits, OptimConfig, OptimKind, OptimSpec,
    Optimizer, ParamOptimizer, PrecisionController, PrecisionPolicy, TensorInfo,
};
use bitopt8::quant::Format;
use bitopt8::util::args::Args;
use bitopt8::util::bench::bench;
use bitopt8::util::json::{num, obj, s, Json};
use bitopt8::util::lanes;
use bitopt8::util::parallel;
use bitopt8::util::rng::Rng;

type Fleet = (Vec<Box<dyn Optimizer>>, Vec<Vec<f32>>, Vec<Vec<f32>>);

/// `(kind, elements, 2-D shape)` per tensor.
type Spec = (OptimKind, usize, Option<(usize, usize)>);

fn fleet(spec: &[Spec], bits: Bits) -> Fleet {
    let mut rng = Rng::new(42);
    let mut opts = Vec::new();
    let mut params: Vec<Vec<f32>> = Vec::new();
    let mut grads: Vec<Vec<f32>> = Vec::new();
    for &(kind, n, shape) in spec {
        let mut cfg = OptimConfig::adam(1e-3, bits);
        cfg.kind = kind;
        opts.push(build(&cfg, n, shape));
        params.push((0..n).map(|_| rng.normal() as f32).collect());
        grads.push((0..n).map(|_| rng.normal() as f32 * 0.01).collect());
    }
    (opts, params, grads)
}

/// Many equal small tensors (the PR-1 workload).
fn adam_many_small(n_tensors: usize, n: usize) -> Vec<Spec> {
    (0..n_tensors).map(|_| (OptimKind::Adam, n, None)).collect()
}

/// Realistic per-layer mix for one reduction-bearing optimizer: a couple
/// of large projections, several medium matrices, many bias/norm vectors.
fn reduction_mix(kind: OptimKind, layers: usize) -> Vec<Spec> {
    let mut spec: Vec<Spec> = Vec::new();
    for _ in 0..layers {
        spec.push((kind, 256 * 1024, Some((256, 1024)))); // attention proj
        spec.push((kind, 128 * 512, Some((128, 512)))); // mlp in
        spec.push((kind, 512 * 128, Some((512, 128)))); // mlp out
        for _ in 0..6 {
            spec.push((kind, 1024, None)); // biases / norms
        }
    }
    spec
}

struct Entry {
    workload: &'static str,
    optimizer: &'static str,
    bits: String,
    variant: &'static str,
    us_per_step: f64,
    iters: usize,
    /// Speedup vs the workload's first (baseline) variant.
    speedup_vs_base: f64,
    /// Optimizer-state bytes per parameter for this fleet (the footprint
    /// axis of the 4 vs 8 vs 32-bit sweep).
    bytes_per_element: f64,
    /// Percentile-clip + unorm-clip events drained across the variant's
    /// bench loop (0 for workloads without stability phases).
    clip_events: u64,
    /// Largest per-shard optimizer-state footprint for the variant's
    /// placement (0 for unsharded workloads) — the memory a single shard
    /// must actually hold.
    max_shard_bytes: u64,
    /// Precision-controller width transitions applied across the bench
    /// loop (0 for workloads without a controller).
    transitions: u64,
    /// Peak optimizer-state footprint across the bench loop: the largest
    /// total seen at any controller review for the adaptive variant, the
    /// static footprint otherwise (0 for workloads that don't track it).
    peak_state_bytes: u64,
}

fn record(e: Entry, out: &mut Vec<Entry>) {
    println!(
        "{:<17} {:<10} {:<22} {:<18} {:>12.1} µs/step {:>8.2}x {:>8.3} B/elem",
        e.workload, e.optimizer, e.bits, e.variant, e.us_per_step, e.speedup_vs_base,
        e.bytes_per_element
    );
    out.push(e);
}

/// Optimizer-state bytes per parameter across a fleet.
fn fleet_bytes_per_element(opts: &[Box<dyn Optimizer>], params: &[Vec<f32>]) -> f64 {
    let state: usize = opts.iter().map(|o| o.state_bytes()).sum();
    let n: usize = params.iter().map(|p| p.len()).sum();
    state as f64 / n.max(1) as f64
}

fn run_workload(
    workload: &'static str,
    optimizer: &'static str,
    spec: &[Spec],
    bits: Bits,
    budget: Duration,
    out: &mut Vec<Entry>,
) {
    let mut base_us = 0.0f64;
    for variant in ["per-tensor", "fused", "streaming"] {
        let (mut opts, mut params, grads) = fleet(spec, bits);
        let r = bench(variant, budget, 2000, || match variant {
            "fused" => fused_update(&mut opts, &mut params, &grads),
            "streaming" => streaming_update(&mut opts, &mut params, &grads),
            _ => {
                for i in 0..opts.len() {
                    opts[i].step(&mut params[i], &grads[i]);
                }
            }
        });
        let us = r.median_ns / 1e3;
        if variant == "per-tensor" {
            base_us = us;
        }
        let e = Entry {
            workload,
            optimizer,
            bits: bits.describe(),
            variant,
            us_per_step: us,
            iters: r.iters,
            speedup_vs_base: base_us / us,
            bytes_per_element: fleet_bytes_per_element(&opts, &params),
            clip_events: 0,
            max_shard_bytes: 0,
            transitions: 0,
            peak_state_bytes: 0,
        };
        record(e, out);
    }
}

/// The state-width sweep: the same fused Adam workload at 32, 8, and 4
/// bits, recording bytes/element alongside step throughput — the Table
/// 1-style footprint/speed tradeoff at every supported code width.
fn run_width_sweep(spec: &[Spec], budget: Duration, out: &mut Vec<Entry>) {
    let mut base_us = 0.0f64;
    for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
        let (mut opts, mut params, grads) = fleet(spec, bits);
        let r = bench("fused", budget, 2000, || {
            fused_update(&mut opts, &mut params, &grads)
        });
        let us = r.median_ns / 1e3;
        if bits == Bits::B32 {
            base_us = us;
        }
        let e = Entry {
            workload: "q4_width_sweep",
            optimizer: "adam",
            bits: bits.describe(),
            variant: "fused",
            us_per_step: us,
            iters: r.iters,
            speedup_vs_base: base_us / us,
            bytes_per_element: fleet_bytes_per_element(&opts, &params),
            clip_events: 0,
            max_shard_bytes: 0,
            transitions: 0,
            peak_state_bytes: 0,
        };
        record(e, out);
    }
}

/// The SIMD sweep: the fused Adam step per code width and format, with the
/// forced-scalar kernels as the baseline variant — elements/sec of the
/// lane-chunked dequantize→update→requantize path vs the identical scalar
/// path (`speedup_vs_base` is the lane speedup; the two are bit-identical,
/// so the delta is pure vectorization).
fn run_simd_sweep(spec: &[Spec], budget: Duration, out: &mut Vec<Entry>) {
    let sweep = [
        Bits::B32,
        Bits::B8 { format: Format::Dynamic, blockwise: true },
        Bits::B8 { format: Format::Linear, blockwise: true },
        Bits::B4 { format: Format::Dynamic, blockwise: true },
        Bits::B4 { format: Format::Linear, blockwise: true },
    ];
    for bits in sweep {
        let mut base_us = 0.0f64;
        for variant in ["scalar", "lanes"] {
            let (mut opts, mut params, grads) = fleet(spec, bits);
            let run = || {
                bench(variant, budget, 2000, || {
                    fused_update(&mut opts, &mut params, &grads)
                })
            };
            let r = if variant == "scalar" { lanes::with_forced_scalar(run) } else { run() };
            let us = r.median_ns / 1e3;
            if variant == "scalar" {
                base_us = us;
            }
            let e = Entry {
                workload: "simd_sweep",
                optimizer: "adam",
                bits: bits.describe(),
                variant,
                us_per_step: us,
                iters: r.iters,
                speedup_vs_base: base_us / us,
                bytes_per_element: fleet_bytes_per_element(&opts, &params),
                clip_events: 0,
                max_shard_bytes: 0,
                transitions: 0,
                peak_state_bytes: 0,
            };
            record(e, out);
        }
    }
}

/// Serial "gradient production" stand-in: one pass over the buffer on the
/// main thread (deterministic xorshift-ish fill), proportional to tensor
/// size like a real runtime transfer.
fn produce(grad: &mut [f32], round: usize) {
    let mut x = 0x9E37_79B9_7F4A_7C15u64 ^ (round as u64);
    for v in grad.iter_mut() {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *v = ((x >> 40) as f32 / (1 << 24) as f32 - 0.5) * 0.02;
    }
}

/// The overlap workload: serial per-tensor gradient production on the main
/// thread, either completed before one fused step (`produce-then-fused`)
/// or overlapped with streaming admission (`streaming`).
fn run_overlap(
    optimizer: &'static str,
    spec: &[Spec],
    bits: Bits,
    budget: Duration,
    out: &mut Vec<Entry>,
) {
    let mut base_us = 0.0f64;
    for variant in ["produce-then-fused", "streaming"] {
        let (mut opts, mut params, mut grads) = fleet(spec, bits);
        let mut round = 0usize;
        let r = bench(variant, budget, 2000, || {
            round += 1;
            if variant == "streaming" {
                let mut stream = StreamingStep::new();
                let tensors = opts.iter_mut().zip(params.iter_mut()).zip(grads.iter_mut());
                for ((opt, p), g) in tensors {
                    produce(g, round);
                    let g: &[f32] = g;
                    stream.push(opt.as_mut(), p.as_mut_slice(), g);
                }
                stream.finish();
            } else {
                for g in grads.iter_mut() {
                    produce(g, round);
                }
                fused_update(&mut opts, &mut params, &grads);
            }
        });
        let us = r.median_ns / 1e3;
        if variant == "produce-then-fused" {
            base_us = us;
        }
        let e = Entry {
            workload: "streaming_overlap",
            optimizer,
            bits: bits.describe(),
            variant,
            us_per_step: us,
            iters: r.iters,
            speedup_vs_base: base_us / us,
            bytes_per_element: fleet_bytes_per_element(&opts, &params),
            clip_events: 0,
            max_shard_bytes: 0,
            transitions: 0,
            peak_state_bytes: 0,
        };
        record(e, out);
    }
}

/// The stability-stress workload: the same fused Adam fleet with and
/// without the stability phases (percentile clip + max_unorm + skip_zeros),
/// a 32x gradient spike every 16th iteration in both. `us_per_step`
/// measures the overhead of the extra phases; `clip_events` (drained from
/// the global counters around the bench loop) proves the stabilized
/// variant actually clipped — a silent no-op would bench identically.
fn run_stability_stress(spec: &[Spec], budget: Duration, out: &mut Vec<Entry>) {
    let bits = Bits::b8_dynamic();
    let mut base_us = 0.0f64;
    for variant in ["baseline", "stabilized"] {
        let mut rng = Rng::new(42);
        let mut opts: Vec<Box<dyn Optimizer>> = Vec::new();
        let mut params: Vec<Vec<f32>> = Vec::new();
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for &(kind, n, shape) in spec {
            let mut cfg = OptimConfig::adam(1e-3, bits);
            cfg.kind = kind;
            if variant == "stabilized" {
                cfg.clip_percentile = 95.0;
                cfg.max_unorm = 0.1;
                cfg.skip_zeros = true;
            }
            opts.push(build(&cfg, n, shape));
            params.push((0..n).map(|_| rng.normal() as f32).collect());
            grads.push((0..n).map(|_| rng.normal() as f32 * 0.01).collect());
        }
        take_clip_events();
        take_unorm_clips();
        let mut round = 0usize;
        let r = bench(variant, budget, 2000, || {
            round += 1;
            let spike = round % 16 == 0;
            if spike {
                // 32x is a power of two: the post-step unscale is exact
                for g in grads.iter_mut() {
                    for v in g.iter_mut() {
                        *v *= 32.0;
                    }
                }
            }
            fused_update(&mut opts, &mut params, &grads);
            if spike {
                for g in grads.iter_mut() {
                    for v in g.iter_mut() {
                        *v /= 32.0;
                    }
                }
            }
        });
        let clip_events = take_clip_events() + take_unorm_clips();
        let us = r.median_ns / 1e3;
        if variant == "baseline" {
            base_us = us;
        }
        let e = Entry {
            workload: "stability_stress",
            optimizer: "adam",
            bits: bits.describe(),
            variant,
            us_per_step: us,
            iters: r.iters,
            speedup_vs_base: base_us / us,
            bytes_per_element: fleet_bytes_per_element(&opts, &params),
            clip_events,
            max_shard_bytes: 0,
            transitions: 0,
            peak_state_bytes: 0,
        };
        record(e, out);
    }
}

/// The shard sweep: the same 8-bit Adam fleet partitioned across 1/2/4/8
/// ZeRO-style shards via the greedy bytes-balanced placement, each shard
/// stepping its tensors as an independent streaming batch. Placement is
/// bit-identical to the unsharded step, so the interesting outputs are
/// `max_shard_bytes` (the footprint one shard must hold — it should fall
/// roughly as 1/N) against `us_per_step` (the dispatch cost of N batches).
fn run_shard_sweep(spec: &[Spec], budget: Duration, out: &mut Vec<Entry>) {
    let bits = Bits::b8_dynamic();
    let mut base_us = 0.0f64;
    for n_shards in [1usize, 2, 4, 8] {
        let (mut opts, mut params, grads) = fleet(spec, bits);
        let state_bytes: Vec<usize> = opts.iter().map(|o| o.state_bytes()).collect();
        let assignment = assign_greedy(&state_bytes, n_shards);
        let mut shard_bytes = vec![0u64; n_shards];
        for (i, &sh) in assignment.iter().enumerate() {
            shard_bytes[sh] += state_bytes[i] as u64;
        }
        let variant = match n_shards {
            1 => "shards1",
            2 => "shards2",
            4 => "shards4",
            _ => "shards8",
        };
        let r = bench(variant, budget, 2000, || {
            sharded_update(&mut opts, &mut params, &grads, &assignment, n_shards)
        });
        let us = r.median_ns / 1e3;
        if n_shards == 1 {
            base_us = us;
        }
        let e = Entry {
            workload: "shard_sweep",
            optimizer: "adam",
            bits: bits.describe(),
            variant,
            us_per_step: us,
            iters: r.iters,
            speedup_vs_base: base_us / us,
            bytes_per_element: fleet_bytes_per_element(&opts, &params),
            clip_events: 0,
            max_shard_bytes: shard_bytes.iter().copied().max().unwrap_or(0),
            transitions: 0,
            peak_state_bytes: 0,
        };
        record(e, out);
    }
}

/// The adaptive-precision workload: static 8-bit Adam vs the runtime
/// precision controller starting at 4-bit, over the same fleet with a
/// 32x gradient spike on tensor 0 every 16th iteration. The controller
/// (cadence 8, spike trigger only — the quant-error and demotion paths
/// are disabled so the transition count stays deterministic) promotes
/// just the spiking tensor, so the adaptive peak footprint must stay
/// strictly below static-8 while the unstable tensor still widens. The
/// per-iteration signal collection (per-tensor squared norms) runs
/// inside the bench loop on purpose: it is part of the controller's
/// price, and `us_per_step` should say so.
fn run_adaptive_precision(n_tensors: usize, n: usize, budget: Duration, out: &mut Vec<Entry>) {
    let infos: Vec<TensorInfo> = (0..n_tensors)
        .map(|i| TensorInfo {
            name: format!("t{i:02}"),
            size: n,
            shape: None,
            padded: n.next_multiple_of(2048),
        })
        .collect();
    let mut rng = Rng::new(42);
    let base_grads: Vec<Vec<f32>> = (0..n_tensors)
        .map(|_| (0..n).map(|_| rng.normal() as f32 * 0.01).collect())
        .collect();
    let mut base_us = 0.0f64;
    for variant in ["static8", "adaptive4"] {
        let bits = if variant == "static8" { Bits::b8_dynamic() } else { Bits::b4_dynamic() };
        let spec = OptimSpec::new(OptimConfig::adam(1e-3, bits));
        let mut popt = ParamOptimizer::build(spec, &infos, None).expect("bench fleet builds");
        let mut ctl = (variant == "adaptive4").then(|| {
            let policy = PrecisionPolicy {
                cadence: 8,
                promote_error: 2.0, // disable the quant-error trigger
                demote_error: 0.0,  // disable demotion
                ..PrecisionPolicy::default()
            };
            PrecisionController::new(policy, &popt)
        });
        let mut params: Vec<Vec<f32>> = (0..n_tensors).map(|_| vec![0.0f32; n]).collect();
        let mut grads = base_grads.clone();
        let mut round = 0usize;
        let r = bench(variant, budget, 2000, || {
            round += 1;
            let spike = round % 16 == 0;
            if spike {
                // 32x is a power of two: the post-step unscale is exact
                for v in grads[0].iter_mut() {
                    *v *= 32.0;
                }
            }
            popt.step_native(&mut params, &grads);
            if let Some(ctl) = ctl.as_mut() {
                let tensor_sq: Vec<f64> = grads
                    .iter()
                    .map(|g| g.iter().map(|&v| v as f64 * v as f64).sum())
                    .collect();
                ctl.observe_step(&tensor_sq, 0, 0, false);
                if ctl.due(round) {
                    ctl.review(round, &mut popt);
                }
            }
            if spike {
                for v in grads[0].iter_mut() {
                    *v /= 32.0;
                }
            }
        });
        let us = r.median_ns / 1e3;
        if variant == "static8" {
            base_us = us;
        }
        let (transitions, peak) = match &ctl {
            Some(c) => (
                c.transitions().len() as u64,
                c.peak_state_bytes().max(popt.state_bytes()) as u64,
            ),
            None => (0, popt.state_bytes() as u64),
        };
        let e = Entry {
            workload: "adaptive_precision",
            optimizer: "adam",
            bits: bits.describe(),
            variant,
            us_per_step: us,
            iters: r.iters,
            speedup_vs_base: base_us / us,
            bytes_per_element: popt.state_bytes() as f64
                / (n_tensors * n).max(1) as f64,
            clip_events: 0,
            max_shard_bytes: 0,
            transitions,
            peak_state_bytes: peak,
        };
        record(e, out);
    }
    let get = |variant: &str| {
        out.iter()
            .find(|e| e.workload == "adaptive_precision" && e.variant == variant)
            .map(|e| e.peak_state_bytes)
            .unwrap_or(0)
    };
    let (st, ad) = (get("static8"), get("adaptive4"));
    println!(
        "adaptive_precision: peak state {ad} bytes vs static-8 {st} bytes ({:.1}% saved)",
        (1.0 - ad as f64 / st.max(1) as f64) * 100.0
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n_tensors = args.get_usize("tensors", 48);
    let n = args.get_usize("n", 4096);
    let layers = args.get_usize("layers", 2);
    let budget = Duration::from_millis(args.get_u64("budget-ms", 1200));
    let out_path = args.get_or("out", "BENCH_fused_step.json").to_string();

    println!(
        "fused_step: adam {n_tensors}x{n}, reduction mix {layers} layers, {} threads",
        parallel::num_threads()
    );
    let mut entries: Vec<Entry> = Vec::new();
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        run_workload(
            "adam_many_small",
            "adam",
            &adam_many_small(n_tensors, n),
            bits,
            budget,
            &mut entries,
        );
    }
    // LAMB exercises the quantized two-phase plan; Adafactor and SM3 are
    // 32-bit by construction, so bench them once.
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        let spec = reduction_mix(OptimKind::Lamb, layers);
        run_workload("reduction_mix", "lamb", &spec, bits, budget, &mut entries);
    }
    run_workload(
        "reduction_mix",
        "adafactor",
        &reduction_mix(OptimKind::Adafactor, layers),
        Bits::B32,
        budget,
        &mut entries,
    );
    run_workload(
        "reduction_mix",
        "sm3",
        &reduction_mix(OptimKind::Sm3, layers),
        Bits::B32,
        budget,
        &mut entries,
    );
    // The overlap workload: serial gradient production hidden behind the
    // streaming step (adam = bandwidth-bound single-phase plans, lamb =
    // multi-phase plans that progress via poll while later gradients are
    // still being produced).
    for bits in [Bits::B32, Bits::b8_dynamic()] {
        run_overlap("adam", &adam_many_small(n_tensors, n), bits, budget, &mut entries);
    }
    run_overlap(
        "lamb",
        &reduction_mix(OptimKind::Lamb, layers),
        Bits::b8_dynamic(),
        budget,
        &mut entries,
    );
    // The width sweep: fused Adam at 32 vs 8 vs 4 bits — bytes/element and
    // step throughput on one axis each (the `bits=4` tentpole numbers).
    run_width_sweep(&adam_many_small(n_tensors, n), budget, &mut entries);
    // The SIMD sweep: lane-chunked vs forced-scalar kernels, per width and
    // format (the scalar-vs-lane tentpole numbers; CI guards the speedup).
    run_simd_sweep(&adam_many_small(n_tensors, n), budget, &mut entries);
    // The stability-stress workload: stabilized (clip + unorm + skip_zeros)
    // vs plain fused Adam under periodic gradient spikes, with clip-event
    // counts proving the phases engaged (CI greps for them).
    run_stability_stress(&adam_many_small(n_tensors, n), budget, &mut entries);
    // The shard sweep: ZeRO-style placement of the 8-bit Adam fleet at
    // 1/2/4/8 shards — max per-shard footprint vs step time (CI greps for
    // the workload so the placement layer stays on the perf record).
    run_shard_sweep(&adam_many_small(n_tensors, n), budget, &mut entries);
    // The adaptive-precision workload: the runtime bit-width controller
    // (start at 4, promote the spiking tensor) vs static 8-bit — peak
    // state bytes and transition counts land in the JSON (CI greps them).
    run_adaptive_precision(n_tensors.min(16), n, budget, &mut entries);

    let results: Vec<Json> = entries
        .iter()
        .map(|e| {
            obj(vec![
                ("workload", s(e.workload)),
                ("optimizer", s(e.optimizer)),
                ("bits", s(&e.bits)),
                ("variant", s(e.variant)),
                ("us_per_step", num(e.us_per_step)),
                ("iters", num(e.iters as f64)),
                ("speedup_vs_base", num(e.speedup_vs_base)),
                ("bytes_per_element", num(e.bytes_per_element)),
                ("clip_events", num(e.clip_events as f64)),
                ("max_shard_bytes", num(e.max_shard_bytes as f64)),
                ("transitions", num(e.transitions as f64)),
                ("peak_state_bytes", num(e.peak_state_bytes as f64)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", s("fused_step")),
        ("threads", num(parallel::num_threads() as f64)),
        ("tensors", num(n_tensors as f64)),
        ("n", num(n as f64)),
        ("layers", num(layers as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(&out_path, doc.to_string() + "\n").expect("write bench json");
    println!("\nwrote {out_path} ({} results)", entries.len());
    println!("(fused: one pool batch per phase per step instead of one dispatch per tensor;");
    println!(" streaming_overlap: the pool updates tensor i while the main thread produces");
    println!(" gradient i+1 — the win grows with serial production cost and core count)");

    // CI guard: every simd_sweep lane entry must beat the scalar baseline
    // by at least the given factor (lane and scalar paths are bit-identical,
    // so a regression here is a pure perf loss, never a tradeoff).
    if let Some(min) = args.get("require-simd-speedup") {
        let min: f64 = min.parse().expect("require-simd-speedup wants a number");
        let mut failed = false;
        for e in entries.iter().filter(|e| e.workload == "simd_sweep" && e.variant == "lanes") {
            if e.speedup_vs_base < min {
                eprintln!(
                    "simd_sweep {}: lane speedup {:.2}x below required {min:.2}x",
                    e.bits, e.speedup_vs_base
                );
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        println!("simd_sweep: all lane variants >= {min:.2}x over scalar baseline");
    }
}
