//! GLUE-style finetuning example: run the 8 synthetic GLUE-like tasks
//! (Table 4 workload) with 8-bit AdamW vs 32-bit AdamW and print the
//! per-task accuracy table.
//!
//!   cargo run --release --example glue_finetune -- [--steps 150] [--seeds 3]

use anyhow::Result;
use bitopt8::config::{parse_optim, RunConfig, Schedule};
use bitopt8::coordinator::Trainer;
use bitopt8::data::glue::GLUE_TASKS;
use bitopt8::runtime::Runtime;
use bitopt8::util::args::Args;
use bitopt8::util::stats::median;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 150);
    let n_seeds = args.get_u64("seeds", 3);
    let rt = Runtime::new(args.get_or("artifacts", "artifacts"))?;

    print!("{:<14}", "optimizer");
    for t in &GLUE_TASKS {
        print!("{:>8}", t.name);
    }
    println!("{:>8}", "mean");

    for (label, bits) in [("adamw-32bit", 32usize), ("adamw-8bit", 8)] {
        print!("{label:<14}");
        let mut means = Vec::new();
        for task in &GLUE_TASKS {
            let mut accs = Vec::new();
            for seed in 0..n_seeds {
                let mut cfg = RunConfig::default();
                cfg.model = "cls_tiny".into();
                cfg.steps = steps;
                cfg.seed = 7000 + seed * 13;
                cfg.eval_every = 0;
                cfg.eval_batches = 8;
                cfg.optim = parse_optim("adamw", bits, "dynamic", true)?;
                cfg.optim.lr = args.get_f64("lr", 1e-3) as f32;
                cfg.optim.weight_decay = 0.01;
                cfg.schedule = Schedule::WarmupLinear { warmup: steps / 10, total: steps };
                let mut tr = Trainer::new(&rt, cfg)?.with_glue_task(task)?;
                let res = tr.train()?;
                accs.push(res.eval_accs.last().map(|&(_, a)| a).unwrap_or(f64::NAN));
            }
            let med = median(&accs);
            means.push(med);
            print!("{med:>8.3}");
        }
        println!("{:>8.3}", means.iter().sum::<f64>() / means.len() as f64);
    }
    println!("\n(paper's Table 4: 8-bit matches 32-bit within noise on every dataset)");
    Ok(())
}
