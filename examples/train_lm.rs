//! End-to-end driver (the full-stack validation example): train a
//! transformer LM through the AOT artifacts — L2 fwd/bwd graph + L1 fused
//! 8-bit optimizer — comparing 8-bit Adam against 32-bit Adam, logging the
//! loss curves.
//!
//!   cargo run --release --example train_lm -- \
//!       --model small_stable --steps 300 [--also-32bit] [--engine hlo]
//!
//! For the ~100M-parameter mandate run: `--model gpt100m_stable` (build
//! artifacts with `make artifacts` first; the gpt100m preset is included
//! by default). Results land in results/train_lm_<model>_<opt>.jsonl.

use anyhow::Result;
use bitopt8::config::{parse_optim, Engine, RunConfig, Schedule};
use bitopt8::coordinator::Trainer;
use bitopt8::runtime::Runtime;
use bitopt8::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "small_stable").to_string();
    let steps = args.get_usize("steps", 300);
    let rt = Runtime::new(args.get_or("artifacts", "artifacts"))?;

    let mut variants: Vec<(&str, usize)> = vec![("adam8", 8)];
    if args.flag("also-32bit") {
        variants.push(("adam32", 32));
    }

    for (tag, bits) in variants {
        let mut cfg = RunConfig::default();
        cfg.model = model.clone();
        cfg.steps = steps;
        cfg.seed = args.get_u64("seed", 42);
        cfg.eval_every = (steps / 6).max(1);
        cfg.eval_batches = 8;
        cfg.optim = parse_optim("adam", bits, "dynamic", true)?;
        cfg.optim.lr = args.get_f64("lr", 6e-4) as f32;
        if bits == 8 {
            // §2.3 stable-embedding policy as a parameter group: embedding
            // tensors keep 32-bit optimizer state, everything else is 8-bit.
            cfg.push_emb32();
        }
        cfg.schedule = Schedule::WarmupLinear { warmup: steps / 10, total: steps };
        cfg.engine = if args.get_or("engine", "native") == "hlo" {
            Engine::Hlo
        } else {
            Engine::Native
        };
        std::fs::create_dir_all("results")?;
        cfg.log_jsonl = Some(format!("results/train_lm_{model}_{tag}.jsonl"));

        println!("=== {} ===", cfg.describe());
        let t0 = std::time::Instant::now();
        let mut tr = Trainer::new(&rt, cfg)?;
        println!(
            "{:.1}M params | optimizer state {:.1} MB",
            tr.n_params() as f64 / 1e6,
            tr.state_bytes() as f64 / 1e6
        );
        println!("{}", tr.param_optimizer().describe());
        let mut last_log = std::time::Instant::now();
        let mut losses = Vec::new();
        for step in 0..steps {
            let loss = tr.train_step()?;
            losses.push(loss);
            if tr.detector.is_unstable() {
                println!("UNSTABLE at step {step}: {:?}", tr.detector.reason());
                break;
            }
            if last_log.elapsed().as_secs() >= 10 || step + 1 == steps || step < 3 {
                let recent =
                    &losses[losses.len().saturating_sub(10)..];
                let avg: f64 = recent.iter().sum::<f64>() / recent.len() as f64;
                println!(
                    "step {:>5}/{steps} | loss {:>7.4} (avg10 {:>7.4}) | {:>6.2} s/step",
                    step + 1,
                    loss,
                    avg,
                    t0.elapsed().as_secs_f64() / (step + 1) as f64
                );
                last_log = std::time::Instant::now();
            }
        }
        let (eval_loss, _) = tr.evaluate()?;
        println!(
            "final: train {:.4} | eval {:.4} (ppl {:.2}) | total {:.1}s | state {:.1} MB",
            losses.last().copied().unwrap_or(f64::NAN),
            eval_loss,
            eval_loss.exp(),
            t0.elapsed().as_secs_f64(),
            tr.state_bytes() as f64 / 1e6
        );
    }
    Ok(())
}
