//! Memory planner: the Table 2 analysis as a user-facing tool — "which
//! models can I finetune on my GPU, and what does the optimizer state
//! cost?"
//!
//!   cargo run --release --example memory_planner -- [--gb 11]

use bitopt8::model::memory::{MemoryModel, OptStateKind, KNOWN_MODELS};
use bitopt8::util::args::Args;

fn main() {
    let args = Args::from_env();
    let budget = args.get_f64("gb", 11.0);
    let mm = MemoryModel::default();

    println!("memory budget: {budget} GB (batch size 1, fp16 weights+grads)\n");
    println!(
        "{:<24} {:>8} {:>11} {:>11} {:>11} {:>11} {:>11}",
        "model", "params", "Adam32", "Adafactor", "Adam8", "Adam4", "fits?"
    );
    for m in KNOWN_MODELS {
        let t32 = mm.total_bytes(&m, OptStateKind::Adam32) / 1e9;
        let taf = mm.total_bytes(&m, OptStateKind::Adafactor) / 1e9;
        let t8 = mm.total_bytes(&m, OptStateKind::Adam8) / 1e9;
        let t4 = mm.total_bytes(&m, OptStateKind::Adam4) / 1e9;
        let verdict = if t32 <= budget {
            "all"
        } else if t8 <= budget {
            "quantized"
        } else if t4 <= budget {
            "4-bit only"
        } else {
            "none"
        };
        println!(
            "{:<24} {:>7.0}M {:>9.1}GB {:>9.1}GB {:>9.1}GB {:>9.1}GB {:>11}",
            m.name,
            m.params / 1e6,
            t32,
            taf,
            t8,
            t4,
            verdict
        );
    }
    println!(
        "\nstate bytes/param: Adam32 {:.2}, Adafactor {:.2}, Adam8 {:.3}, Momentum8 {:.3}, \
         Adam4 {:.3}",
        OptStateKind::Adam32.bytes_per_param(),
        OptStateKind::Adafactor.bytes_per_param(),
        OptStateKind::Adam8.bytes_per_param(),
        OptStateKind::Momentum8.bytes_per_param(),
        OptStateKind::Adam4.bytes_per_param()
    );
}
