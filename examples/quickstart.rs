//! Quickstart: the paper's "two-line change" — swap a 32-bit optimizer for
//! the 8-bit (or 4-bit) one — shown on a toy regression, plus direct use
//! of the block-wise quantizer and the parameter-group surface (per-tensor
//! precision policy: §2.3 stable embeddings at 32-bit, attention at 4-bit
//! per Li et al. 2023). No artifacts needed (pure native engine).
//!
//! Run: `cargo run --release --example quickstart`

use bitopt8::optim::{
    build, Bits, GroupOverride, OptimConfig, OptimSpec, ParamOptimizer, TensorInfo,
};
use bitopt8::quant::{dynamic_tree, BlockQuantizer, BLOCK};
use bitopt8::util::rng::Rng;
use std::sync::Arc;

fn main() {
    // ---- block-wise quantization of a tensor ------------------------------
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..100_000).map(|_| (rng.normal() * 0.01) as f32).collect();
    let bq = BlockQuantizer::new(Arc::new(dynamic_tree::dynamic_signed()), BLOCK);
    let q = bq.quantize(&x);
    let y = bq.dequantize(&q);
    let max_err = x
        .iter()
        .zip(&y)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "quantized {} floats ({} KB) into {} KB, max abs roundtrip error {:.2e}",
        x.len(),
        x.len() * 4 / 1024,
        q.bytes() / 1024,
        max_err
    );

    // ---- 8-bit Adam as a drop-in replacement ------------------------------
    // the "two-line change": Bits::B32 -> Bits::b8_dynamic()
    let n = 1 << 20;
    let target: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    for bits in [Bits::B32, Bits::b8_dynamic(), Bits::b4_dynamic()] {
        let mut opt = build(&OptimConfig::adam(0.05, bits), n, None);
        let mut p = vec![0.0f32; n];
        let t0 = std::time::Instant::now();
        for _ in 0..100 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        let mse: f32 =
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n as f32;
        println!(
            "{:<28} 100 steps on {}M params: mse {:.2e}, state {:>6.2} MB, {:.2}s",
            opt.name(),
            n >> 20,
            mse,
            opt.state_bytes() as f64 / 1e6,
            t0.elapsed().as_secs_f64()
        );
    }
    println!("same update rule at every width: 4x (8-bit) / 8x (4-bit) smaller state.");

    // ---- parameter groups: per-tensor precision policy (§2.3) -------------
    // One spec drives a whole model: 8-bit dynamic block-wise everywhere,
    // except the embedding tensors which keep 32-bit state (the
    // stable-embedding policy), spelled as a single group override.
    let spec = OptimSpec::with_groups(
        OptimConfig::adam(1e-3, Bits::b8_dynamic()),
        vec![
            GroupOverride::emb32(),
            // and the attention projections drop to 4-bit packed state
            GroupOverride::parse("block?.attn.*:bits=4").expect("static override"),
        ],
    );
    let tensors: Vec<TensorInfo> = [
        ("embed.tok", 50_000 * 64),
        ("embed.pos", 512 * 64),
        ("block0.attn.wq", 64 * 64),
        ("block0.mlp.w1", 64 * 256),
        ("lm_head", 64 * 50_000),
    ]
    .into_iter()
    .map(|(name, size)| TensorInfo { name: name.into(), size, shape: None, padded: size })
    .collect();
    let popt = ParamOptimizer::build(spec, &tensors, None).expect("valid spec");
    println!("\nmixed-precision group layout:");
    println!("{}", popt.describe());
}
