"""L2 optimizer-update graphs built on the L1 Pallas kernels.

Each graph is shape-specialized to one (real length, padded length) pair
and lowered by ``aot.py`` to ``adam8_n{npad}.hlo.txt`` /
``momentum8_n{npad}.hlo.txt``. The Rust runtime compiles one executable per
distinct parameter-tensor size and calls it every step with the u8 state
buffers it owns.

Padding contract: params/grads travel at their real length `n`; the
quantized state (codes + absmax) lives at the padded length `npad` (zeros
in the pad region never affect a block absmax, and a zero state + zero
grad never moves a padded lane).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import adam8bit, momentum8bit
from .kernels.blockwise import BLOCK


def padded(n: int, block: int = BLOCK) -> int:
    return -(-n // block) * block


def _pad(x, npad):
    n = x.shape[0]
    if n == npad:
        return x
    return jnp.concatenate([x, jnp.zeros((npad - n,), x.dtype)])


def make_adam8_step(n: int, block: int = BLOCK):
    """fn(hp[8], p[n], g[n], c1[npad], a1[nb], c2[npad], a2[nb])
         -> (p'[n], c1', a1', c2', a2')  — the per-size AOT graph."""
    npad = padded(n, block)
    update = adam8bit.build_adam8_update(npad, block)

    def fn(hp, p, g, c1, a1, c2, a2):
        p_pad = _pad(p, npad)
        g_pad = _pad(g, npad)
        p_new, c1, a1, c2, a2 = update(hp, p_pad, g_pad, c1, a1, c2, a2)
        return (p_new[:n], c1, a1, c2, a2)

    nb = npad // block
    example = (
        jax.ShapeDtypeStruct((8,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((npad,), jnp.uint8),
        jax.ShapeDtypeStruct((nb,), jnp.float32),
        jax.ShapeDtypeStruct((npad,), jnp.uint8),
        jax.ShapeDtypeStruct((nb,), jnp.float32),
    )
    return fn, example


def make_momentum8_step(n: int, block: int = BLOCK):
    """fn(hp[8], p[n], g[n], c[npad], a[nb]) -> (p'[n], c', a')."""
    npad = padded(n, block)
    update = momentum8bit.build_momentum8_update(npad, block)

    def fn(hp, p, g, c, a):
        p_new, c, a = update(hp, _pad(p, npad), _pad(g, npad), c, a)
        return (p_new[:n], c, a)

    nb = npad // block
    example = (
        jax.ShapeDtypeStruct((8,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((npad,), jnp.uint8),
        jax.ShapeDtypeStruct((nb,), jnp.float32),
    )
    return fn, example


def make_quantize_graph(n: int, signed: bool, block: int = BLOCK):
    """Standalone block-wise quantize graph (engine-parity tests)."""
    from .kernels import blockwise, codebooks

    cb = codebooks.dynamic_signed() if signed else codebooks.dynamic_unsigned()
    assert n % block == 0

    def fn(x):
        codes, absmax = blockwise.quantize_blockwise(x, cb, block)
        return (codes, absmax)

    example = (jax.ShapeDtypeStruct((n,), jnp.float32),)
    return fn, example


def make_dequantize_graph(n: int, signed: bool, block: int = BLOCK):
    from .kernels import blockwise, codebooks

    cb = codebooks.dynamic_signed() if signed else codebooks.dynamic_unsigned()
    assert n % block == 0

    def fn(codes, absmax):
        return (blockwise.dequantize_blockwise(codes, absmax, cb, block),)

    example = (
        jax.ShapeDtypeStruct((n,), jnp.uint8),
        jax.ShapeDtypeStruct((n // block,), jnp.float32),
    )
    return fn, example
