"""L2: transformer language model / sequence classifier in JAX.

The compute graphs the coordinator drives at runtime — lowered once by
``aot.py`` to HLO text. Two graph families:

* ``lm``  — next-token LM: ``train_step(params..., tokens[B,S+1])`` returns
  ``(loss, grad_0, ..., grad_{k-1})``.
* ``cls`` — sequence classification (the GLUE-like Table 4 workload):
  ``train_step(params..., tokens[B,S], labels[B])``.

The **stable embedding layer** (paper §2.3) is a graph-level switch:
Xavier-uniform init (done host-side from the manifest) + LayerNorm applied
*before* adding position embeddings. The standard embedding follows the
fairseq recipe the paper's Appendix C describes: N(0, 1/√d) init with
√d output scaling. Keeping 32-bit optimizer state for the embedding is a
host-side (Rust) optimizer-policy decision, not a graph change.

Parameters travel as a flat, name-sorted list so the Rust side can map
HLO parameter positions to tensors via the manifest.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    vocab: int = 2048
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 128
    batch: int = 16
    stable_embedding: bool = False
    task: str = "lm"  # "lm" | "cls"
    n_classes: int = 2  # cls only
    init_std_scale: float = 1.0

    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: Model presets. `gpt100m` is the E2E-mandate scale (~110M params).
PRESETS: dict[str, ModelConfig] = {
    "nano": ModelConfig("nano", vocab=512, d_model=64, n_layers=2, n_heads=2,
                        d_ff=256, seq_len=64, batch=16),
    "tiny": ModelConfig("tiny", vocab=2048, d_model=128, n_layers=2, n_heads=4,
                        d_ff=512, seq_len=128, batch=16),
    "small": ModelConfig("small", vocab=4096, d_model=256, n_layers=4, n_heads=4,
                         d_ff=1024, seq_len=128, batch=16),
    "medium": ModelConfig("medium", vocab=8192, d_model=512, n_layers=8, n_heads=8,
                          d_ff=2048, seq_len=128, batch=8),
    "gpt100m": ModelConfig("gpt100m", vocab=16384, d_model=768, n_layers=12,
                           n_heads=12, d_ff=3072, seq_len=256, batch=4),
    "cls_tiny": ModelConfig("cls_tiny", vocab=1024, d_model=128, n_layers=2,
                            n_heads=4, d_ff=512, seq_len=64, batch=32,
                            task="cls", n_classes=4),
}


def config_from(preset: str, stable_embedding: bool, batch: int | None = None,
                seq_len: int | None = None) -> ModelConfig:
    import dataclasses
    cfg = PRESETS[preset]
    kw = {"stable_embedding": stable_embedding}
    if batch is not None:
        kw["batch"] = batch
    if seq_len is not None:
        kw["seq_len"] = seq_len
    return dataclasses.replace(cfg, **kw)


# --------------------------------------------------------------- parameters
@dataclass
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    #: host-side initializer: "zeros" | "ones" | "normal:<std>" | "xavier_uniform"
    init: str
    #: embedding-layer flag — the coordinator gives these tensors 32-bit
    #: optimizer state when the stable-embedding policy is on (§2.3).
    is_embedding: bool = False


def param_specs(cfg: ModelConfig) -> list[ParamSpec]:
    """Name-sorted parameter inventory (the manifest contract with Rust)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    emb_init = ("xavier_uniform" if cfg.stable_embedding
                else f"normal:{1.0 / math.sqrt(d):.8e}")
    specs = [
        ParamSpec("embed.tok", (v, d), emb_init, is_embedding=True),
        ParamSpec("embed.pos", (cfg.seq_len, d), "normal:0.02", is_embedding=True),
        ParamSpec("final_ln.bias", (d,), "zeros"),
        ParamSpec("final_ln.scale", (d,), "ones"),
    ]
    if cfg.stable_embedding:
        specs += [
            ParamSpec("embed.ln.bias", (d,), "zeros"),
            ParamSpec("embed.ln.scale", (d,), "ones"),
        ]
    if cfg.task == "lm":
        specs.append(ParamSpec("lm_head", (d, v), f"normal:{1.0 / math.sqrt(d):.8e}"))
    else:
        specs.append(ParamSpec("cls_head", (d, cfg.n_classes),
                               f"normal:{1.0 / math.sqrt(d):.8e}"))
    std = 0.02 * cfg.init_std_scale
    resid_std = std / math.sqrt(2.0 * cfg.n_layers)
    for l in range(cfg.n_layers):
        p = f"layers.{l:02d}"
        specs += [
            ParamSpec(f"{p}.ln1.bias", (d,), "zeros"),
            ParamSpec(f"{p}.ln1.scale", (d,), "ones"),
            ParamSpec(f"{p}.ln2.bias", (d,), "zeros"),
            ParamSpec(f"{p}.ln2.scale", (d,), "ones"),
            ParamSpec(f"{p}.attn.wq", (d, d), f"normal:{std:.8e}"),
            ParamSpec(f"{p}.attn.wk", (d, d), f"normal:{std:.8e}"),
            ParamSpec(f"{p}.attn.wv", (d, d), f"normal:{std:.8e}"),
            ParamSpec(f"{p}.attn.wo", (d, d), f"normal:{resid_std:.8e}"),
            ParamSpec(f"{p}.mlp.w1", (d, ff), f"normal:{std:.8e}"),
            ParamSpec(f"{p}.mlp.b1", (ff,), "zeros"),
            ParamSpec(f"{p}.mlp.w2", (ff, d), f"normal:{resid_std:.8e}"),
            ParamSpec(f"{p}.mlp.b2", (d,), "zeros"),
        ]
    specs.sort(key=lambda s: s.name)
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Reference initializer (tests / python-side experiments). The Rust
    coordinator re-implements this from the manifest init strings."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = {}
    for spec in param_specs(cfg):
        if spec.init == "zeros":
            arr = np.zeros(spec.shape, np.float32)
        elif spec.init == "ones":
            arr = np.ones(spec.shape, np.float32)
        elif spec.init == "xavier_uniform":
            fan_in, fan_out = spec.shape[0], spec.shape[-1]
            a = math.sqrt(6.0 / (fan_in + fan_out))
            arr = rng.uniform(-a, a, spec.shape).astype(np.float32)
        elif spec.init.startswith("normal:"):
            std = float(spec.init.split(":")[1])
            arr = (rng.standard_normal(spec.shape) * std).astype(np.float32)
        else:
            raise ValueError(spec.init)
        out[spec.name] = jnp.asarray(arr)
    return out


# ------------------------------------------------------------------ forward
def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(cfg: ModelConfig, p: dict, prefix: str, x, causal: bool):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim()
    q = (x @ p[f"{prefix}.wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p[f"{prefix}.wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ p[f"{prefix}.wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e9)
    att = jax.nn.softmax(scores, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[f"{prefix}.wo"]


def _embed(cfg: ModelConfig, p: dict, tokens):
    s = tokens.shape[1]
    tok = p["embed.tok"][tokens]
    if cfg.stable_embedding:
        # §2.3: LayerNorm *before* adding position embeddings.
        tok = _layer_norm(tok, p["embed.ln.scale"], p["embed.ln.bias"])
        return tok + p["embed.pos"][None, :s]
    # fairseq recipe (Appendix C): N(0, 1/√d) init scaled up by √d.
    return tok * math.sqrt(cfg.d_model) + p["embed.pos"][None, :s]


def forward(cfg: ModelConfig, p: dict, tokens):
    """Token ids [B,S] -> final hidden states [B,S,D]."""
    x = _embed(cfg, p, tokens)
    causal = cfg.task == "lm"
    for l in range(cfg.n_layers):
        pre = f"layers.{l:02d}"
        h = _layer_norm(x, p[f"{pre}.ln1.scale"], p[f"{pre}.ln1.bias"])
        x = x + _attention(cfg, p, f"{pre}.attn", h, causal)
        h = _layer_norm(x, p[f"{pre}.ln2.scale"], p[f"{pre}.ln2.bias"])
        h = jax.nn.gelu(h @ p[f"{pre}.mlp.w1"] + p[f"{pre}.mlp.b1"])
        x = x + (h @ p[f"{pre}.mlp.w2"] + p[f"{pre}.mlp.b2"])
    return _layer_norm(x, p["final_ln.scale"], p["final_ln.bias"])


def lm_loss(cfg: ModelConfig, p: dict, tokens):
    """Next-token cross-entropy; tokens [B, S+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    hid = forward(cfg, p, inp)
    logits = hid @ p["lm_head"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def cls_loss(cfg: ModelConfig, p: dict, tokens, labels):
    """Mean-pooled classification cross-entropy; also returns accuracy."""
    hid = forward(cfg, p, tokens)
    pooled = jnp.mean(hid, axis=1)
    logits = pooled @ p["cls_head"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return jnp.mean(nll), acc


# --------------------------------------------------------------- step graphs
def make_train_step(cfg: ModelConfig):
    """Return (fn, example_args): the AOT-lowered training-step graph.

    lm:  fn(*params, tokens[B,S+1]) -> (loss, *grads)
    cls: fn(*params, tokens[B,S], labels[B]) -> (loss, acc, *grads)
    """
    names = [s.name for s in param_specs(cfg)]

    if cfg.task == "lm":
        def fn(*args):
            params = dict(zip(names, args[:len(names)]))
            tokens = args[len(names)]
            loss, grads = jax.value_and_grad(
                lambda pp: lm_loss(cfg, pp, tokens))(params)
            return (loss, *[grads[n] for n in names])

        example = tuple(
            jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in param_specs(cfg)
        ) + (jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32),)
    else:
        def fn(*args):
            params = dict(zip(names, args[:len(names)]))
            tokens = args[len(names)]
            labels = args[len(names) + 1]
            (loss, acc), grads = jax.value_and_grad(
                lambda pp: cls_loss(cfg, pp, tokens, labels), has_aux=True)(params)
            return (loss, acc, *[grads[n] for n in names])

        example = tuple(
            jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in param_specs(cfg)
        ) + (
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
        )
    return fn, example


def make_eval_step(cfg: ModelConfig):
    """Loss-only graph (validation; no gradients)."""
    names = [s.name for s in param_specs(cfg)]

    if cfg.task == "lm":
        def fn(*args):
            params = dict(zip(names, args[:len(names)]))
            return (lm_loss(cfg, params, args[len(names)]),)

        example = tuple(
            jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in param_specs(cfg)
        ) + (jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32),)
    else:
        def fn(*args):
            params = dict(zip(names, args[:len(names)]))
            loss, acc = cls_loss(cfg, params, args[len(names)], args[len(names) + 1])
            return (loss, acc)

        example = tuple(
            jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in param_specs(cfg)
        ) + (
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
        )
    return fn, example


def n_params(cfg: ModelConfig) -> int:
    return sum(math.prod(s.shape) for s in param_specs(cfg))
