"""Quantization codebooks — Python mirror of ``rust/src/quant/``.

The construction here is kept line-for-line equivalent to the Rust
implementation (all arithmetic in f64, decimal-literal decade scales, cast
to f32 at the end) so the Pallas/HLO engine and the native Rust engine use
bit-identical `Q^map` tables. The integration test
``rust/tests/engine_parity.rs`` checks this through the artifact manifest.
"""

from __future__ import annotations

import numpy as np

#: Decade scales as decimal literals (same literals as Rust DECADE_SCALE).
_DECADE_SCALE = [1.0, 1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6]


def _decade_midpoints(n: int) -> list[float]:
    """Midpoints of linspace(0.1, 1.0, n+1), computed exactly like Rust."""
    lo, hi = 0.1, 1.0
    step = (hi - lo) / n
    out = []
    for i in range(n):
        a = lo + step * i
        b = lo + step * (i + 1)
        out.append(0.5 * (a + b))
    return out


def _tree_magnitudes(extra_fraction_bit: bool, inverse: bool) -> list[float]:
    out = []
    for e in range(7):
        f = (min(e, 6) if inverse else 6 - e) + (1 if extra_fraction_bit else 0)
        n = 1 << f
        mids = _decade_midpoints(n)
        scale = _DECADE_SCALE[e]
        for i, m in enumerate(mids):
            if e == 0 and i == n - 1:
                out.append(1.0)  # exact absmax code (zero-error outliers)
            else:
                out.append(m * scale)
    return out


def dynamic_signed() -> np.ndarray:
    """Signed dynamic tree quantization (first Adam state / momentum)."""
    mags = _tree_magnitudes(False, False)
    assert len(mags) == 127
    vals = []
    for m in mags:
        vals.append(np.float32(m))
        vals.append(np.float32(-m))
    vals.append(np.float32(0.0))
    vals.append(np.float32(1e-7))
    return np.sort(np.array(vals, dtype=np.float32))


def dynamic_unsigned() -> np.ndarray:
    """Unsigned dynamic quantization (§2.2) — sign bit re-purposed as an
    extra fixed fraction bit, for the strictly positive second Adam state."""
    mags = _tree_magnitudes(True, False)
    assert len(mags) == 254
    vals = [np.float32(m) for m in mags]
    vals.append(np.float32(0.0))
    vals.append(np.float32(1e-7))
    return np.sort(np.array(vals, dtype=np.float32))


def linear_signed() -> np.ndarray:
    """Linear baseline: { i/127 : i in -127..127 } (ablation rows)."""
    return np.sort(np.array([i / 127.0 for i in range(-127, 128)], dtype=np.float32))


def linear_unsigned() -> np.ndarray:
    return np.array([i / 255.0 for i in range(256)], dtype=np.float32)


def by_name(name: str) -> np.ndarray:
    return {
        "dynamic_signed": dynamic_signed,
        "dynamic_unsigned": dynamic_unsigned,
        "linear_signed": linear_signed,
        "linear_unsigned": linear_unsigned,
    }[name]()


def midpoints(codebook: np.ndarray) -> np.ndarray:
    """Decision boundaries between adjacent codebook values (f32 math,
    same as Rust: 0.5 * (v[i] + v[i+1]))."""
    cb = codebook.astype(np.float32)
    return (np.float32(0.5) * (cb[:-1] + cb[1:])).astype(np.float32)
