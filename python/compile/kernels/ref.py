"""Pure-jnp reference oracle for the Pallas kernels.

Everything here is deliberately simple, vectorized jnp with no pallas —
the CORE correctness signal for L1. pytest compares each Pallas kernel
against these functions; Rust's native engine is cross-checked against the
same semantics through the HLO artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import codebooks


def pad_to_blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Zero-pad a 1-D array to a multiple of `block` (zeros never raise a
    block absmax, so padding does not perturb quantization of real data)."""
    n = x.shape[0]
    rem = (-n) % block
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,), dtype=x.dtype)])
    return x


def quantize_blockwise(x, codebook: np.ndarray, block: int):
    """Block-wise quantization (Eq. 4): per-block absmax normalization then
    nearest-codebook-value encoding. Returns (codes u8 [n], absmax f32
    [n/block]); `x` must already be padded to a block multiple."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    assert n % block == 0, "pad first"
    xb = x.reshape(-1, block)
    absmax = jnp.max(jnp.abs(xb), axis=1)
    inv = jnp.where(absmax > 0, 1.0 / absmax, 1.0).astype(jnp.float32)
    xn = xb * inv[:, None]
    mids = jnp.asarray(codebooks.midpoints(codebook))
    # count of midpoints <= value == nearest index (ties round up), exactly
    # the Rust Codebook::encode semantics.
    codes = jnp.searchsorted(mids, xn.reshape(-1), side="right").astype(jnp.uint8)
    return codes.reshape(n), absmax.astype(jnp.float32)


def dequantize_blockwise(codes, absmax, codebook: np.ndarray, block: int):
    """Inverse: codebook lookup then denormalize by the block absmax."""
    cb = jnp.asarray(codebook)
    vals = cb[codes.astype(jnp.int32)].reshape(-1, block)
    return (vals * absmax[:, None]).reshape(-1)


def adam_update(p, g, m, r, lr, beta1, beta2, eps, weight_decay, t):
    """32-bit Adam update rule (Eq. 2 + bias correction), elementwise —
    the same rule as Rust `Adam::update_rule` with coupled weight decay."""
    g = jnp.asarray(g, jnp.float32)
    if weight_decay != 0.0:
        g = g + weight_decay * p
    m = beta1 * m + (1.0 - beta1) * g
    r = beta2 * r + (1.0 - beta2) * g * g
    bias1 = 1.0 - beta1**t
    bias2 = 1.0 - beta2**t
    m_hat = m / bias1
    r_hat = r / bias2
    p = p - lr * m_hat / (jnp.sqrt(r_hat) + eps)
    return p, m, r


def adam8bit_update(p, g, codes1, absmax1, codes2, absmax2,
                    cb1: np.ndarray, cb2: np.ndarray, block: int,
                    lr, beta1, beta2, eps, weight_decay, t):
    """Reference 8-bit Adam step (Figure 1): dequantize → 32-bit update →
    requantize. Arrays must be padded to a block multiple."""
    m = dequantize_blockwise(codes1, absmax1, cb1, block)
    r = dequantize_blockwise(codes2, absmax2, cb2, block)
    p, m, r = adam_update(p, g, m, r, lr, beta1, beta2, eps, weight_decay, t)
    codes1, absmax1 = quantize_blockwise(m, cb1, block)
    codes2, absmax2 = quantize_blockwise(r, cb2, block)
    return p, codes1, absmax1, codes2, absmax2


def momentum_update(p, g, m, lr, beta, weight_decay, t):
    """SGD+momentum (Eq. 1): m_t = β m + g (m_0 = g_0)."""
    g = jnp.asarray(g, jnp.float32)
    if weight_decay != 0.0:
        g = g + weight_decay * p
    m = jnp.where(t <= 1, g, beta * m + g)
    p = p - lr * m
    return p, m


def momentum8bit_update(p, g, codes, absmax, cb: np.ndarray, block: int,
                        lr, beta, weight_decay, t):
    m = dequantize_blockwise(codes, absmax, cb, block)
    p, m = momentum_update(p, g, m, lr, beta, weight_decay, t)
    codes, absmax = quantize_blockwise(m, cb, block)
    return p, codes, absmax
