"""L1 Pallas kernel: fused 8-bit Momentum update (Eq. 1 + §2 pipeline).

hp = [lr, beta, weight_decay, is_first_step, 0, 0, 0, 0]; the first step
initializes the state with the raw gradient (m_0 = g_0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .blockwise import BLOCK, _encode


def _momentum8_kernel(hp_ref, cb_ref, mids_ref, p_ref, g_ref, c_ref, a_ref,
                      p_out, c_out, a_out):
    cb, mids = cb_ref[...], mids_ref[...]
    hp = hp_ref[...]
    lr, beta, wd, first = hp[0], hp[1], hp[2], hp[3]
    p = p_ref[...]
    g = g_ref[...] + wd * p
    m = cb[c_ref[...].astype(jnp.int32)] * a_ref[0]
    m = jnp.where(first > 0.5, g, beta * m + g)
    p = p - lr * m
    am = jnp.max(jnp.abs(m))
    inv = jnp.where(am > 0, 1.0 / am, 1.0).astype(jnp.float32)
    p_out[...] = p
    c_out[...] = _encode(m * inv, mids)
    a_out[...] = am.reshape(1)


def build_momentum8_update(n: int, block: int = BLOCK):
    """fn(hp, p, g, c, a) -> (p', c', a') over padded length-n tensors."""
    assert n % block == 0
    from . import codebooks

    cb = jnp.asarray(codebooks.dynamic_signed())
    mids = jnp.asarray(codebooks.midpoints(codebooks.dynamic_signed()))
    grid = n // block

    def update(hp, p, g, c, a):
        return pl.pallas_call(
            _momentum8_kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((8,), lambda i: (0,)),
                pl.BlockSpec((cb.shape[0],), lambda i: (0,)),
                pl.BlockSpec((mids.shape[0],), lambda i: (0,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (i,)),
            ],
            out_specs=[
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n,), jnp.float32),
                jax.ShapeDtypeStruct((n,), jnp.uint8),
                jax.ShapeDtypeStruct((grid,), jnp.float32),
            ],
            interpret=True,
        )(hp, cb, mids, p, g, c, a)

    return update


def make_hp(lr: float, beta: float, weight_decay: float, t: int) -> np.ndarray:
    return np.array([lr, beta, weight_decay, 1.0 if t <= 1 else 0.0,
                     0.0, 0.0, 0.0, 0.0], dtype=np.float32)
