"""L1 Pallas kernel: fused 8-bit Adam update (paper §2, Figure 1).

One grid step = one quantization block. Inside the kernel (all VMEM):
dequantize both 8-bit states to f32, apply the exact 32-bit Adam rule,
requantize, and apply the parameter update — a single pass over HBM per
state tensor (1 read of u8 codes + 1 write), which is the property that
makes the paper's optimizer *faster* than 32-bit Adam.

Hyperparameters arrive as an 8-lane f32 vector so the lowered HLO artifact
is reusable across steps / LR schedules without recompilation:
  hp = [lr, beta1, beta2, eps, weight_decay, bias_c1, bias_c2, unused]
with bias_ck = 1 - beta_k^t precomputed by the host (Rust coordinator).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .blockwise import BLOCK, _encode


def _adam8_kernel(hp_ref, cb1_ref, mids1_ref, cb2_ref, mids2_ref,
                  p_ref, g_ref, c1_ref, a1_ref, c2_ref, a2_ref,
                  p_out, c1_out, a1_out, c2_out, a2_out):
    cb1, mids1 = cb1_ref[...], mids1_ref[...]
    cb2, mids2 = cb2_ref[...], mids2_ref[...]
    hp = hp_ref[...]
    lr, b1, b2, eps, wd, bias1, bias2 = (hp[0], hp[1], hp[2], hp[3], hp[4],
                                         hp[5], hp[6])
    p = p_ref[...]
    g = g_ref[...]
    # dequantize states (codebook lookup × block absmax)
    m = cb1[c1_ref[...].astype(jnp.int32)] * a1_ref[0]
    r = cb2[c2_ref[...].astype(jnp.int32)] * a2_ref[0]
    # 32-bit Adam rule (coupled weight decay, like Rust update_rule)
    g = g + wd * p
    m = b1 * m + (1.0 - b1) * g
    r = b2 * r + (1.0 - b2) * g * g
    p = p - lr * (m / bias1) / (jnp.sqrt(r / bias2) + eps)
    # requantize both states
    am1 = jnp.max(jnp.abs(m))
    inv1 = jnp.where(am1 > 0, 1.0 / am1, 1.0).astype(jnp.float32)
    am2 = jnp.max(jnp.abs(r))
    inv2 = jnp.where(am2 > 0, 1.0 / am2, 1.0).astype(jnp.float32)
    p_out[...] = p
    c1_out[...] = _encode(m * inv1, mids1)
    a1_out[...] = am1.reshape(1)
    c2_out[...] = _encode(r * inv2, mids2)
    a2_out[...] = am2.reshape(1)


def build_adam8_update(n: int, block: int = BLOCK):
    """Return a jittable fn(hp, p, g, c1, a1, c2, a2) -> (p', c1', a1',
    c2', a2') over padded length-n tensors. This is what aot.py lowers to
    the per-size HLO artifacts `adam8_update_n{n}.hlo.txt`."""
    assert n % block == 0
    from . import codebooks

    cb1 = jnp.asarray(codebooks.dynamic_signed())
    mids1 = jnp.asarray(codebooks.midpoints(codebooks.dynamic_signed()))
    cb2 = jnp.asarray(codebooks.dynamic_unsigned())
    mids2 = jnp.asarray(codebooks.midpoints(codebooks.dynamic_unsigned()))
    grid = n // block

    def update(hp, p, g, c1, a1, c2, a2):
        return pl.pallas_call(
            _adam8_kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((8,), lambda i: (0,)),      # hp broadcast
                pl.BlockSpec((cb1.shape[0],), lambda i: (0,)),    # codebook 1
                pl.BlockSpec((mids1.shape[0],), lambda i: (0,)),  # midpoints 1
                pl.BlockSpec((cb2.shape[0],), lambda i: (0,)),    # codebook 2
                pl.BlockSpec((mids2.shape[0],), lambda i: (0,)),  # midpoints 2
                pl.BlockSpec((block,), lambda i: (i,)),  # p
                pl.BlockSpec((block,), lambda i: (i,)),  # g
                pl.BlockSpec((block,), lambda i: (i,)),  # codes1
                pl.BlockSpec((1,), lambda i: (i,)),      # absmax1
                pl.BlockSpec((block,), lambda i: (i,)),  # codes2
                pl.BlockSpec((1,), lambda i: (i,)),      # absmax2
            ],
            out_specs=[
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n,), jnp.float32),
                jax.ShapeDtypeStruct((n,), jnp.uint8),
                jax.ShapeDtypeStruct((grid,), jnp.float32),
                jax.ShapeDtypeStruct((n,), jnp.uint8),
                jax.ShapeDtypeStruct((grid,), jnp.float32),
            ],
            interpret=True,
        )(hp, cb1, mids1, cb2, mids2, p, g, c1, a1, c2, a2)

    return update


def make_hp(lr: float, beta1: float, beta2: float, eps: float,
            weight_decay: float, t: int) -> np.ndarray:
    """Pack the hyperparameter vector the kernel consumes."""
    bias1 = 1.0 - beta1 ** t
    bias2 = 1.0 - beta2 ** t
    return np.array([lr, beta1, beta2, eps, weight_decay, bias1, bias2, 0.0],
                    dtype=np.float32)
