"""L1 Pallas kernels: block-wise quantize / dequantize (paper §2.1).

TPU mapping of the paper's CUDA kernels (DESIGN.md §Hardware-Adaptation):
each quantization block of B=2048 elements is one Pallas grid step whose
operands live in VMEM; the absmax is a VMEM-local reduction (the shared-
memory reduction of the CUDA version), and the codebook search is a
vectorized broadcast-compare against the 256-entry table (VPU-friendly,
replacing the warp binary search). The codebook/midpoint tables are kernel
*inputs* with a constant index map, i.e. resident in VMEM across the whole
grid. interpret=True everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU perf is estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

#: The paper's block size (§2.1).
BLOCK = 2048


def _encode(xn: jnp.ndarray, mids: jnp.ndarray) -> jnp.ndarray:
    """Nearest-codebook-index = count of decision boundaries <= value,
    i.e. searchsorted(side="right") — identical tie semantics to Rust
    `Codebook::encode` (ties round toward the larger value).

    searchsorted is O(log 256) per element and lowers fine in interpret
    mode. On a real-TPU Mosaic build this would become the O(256)
    broadcast-compare + sum (`(mids[None,:] <= xn[:,None]).sum(1)`), which
    trades flops for VPU-friendly regularity; both compute the same index.
    """
    return jnp.searchsorted(mids, xn, side="right").astype(jnp.uint8)


def _quantize_kernel(mids_ref, x_ref, codes_ref, absmax_ref):
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x))
    inv = jnp.where(absmax > 0, 1.0 / absmax, 1.0).astype(jnp.float32)
    codes_ref[...] = _encode(x * inv, mids_ref[...])
    absmax_ref[...] = absmax.reshape(1)


def _dequantize_kernel(cb_ref, codes_ref, absmax_ref, out_ref):
    vals = cb_ref[...][codes_ref[...].astype(jnp.int32)]
    out_ref[...] = vals * absmax_ref[0]


@functools.partial(jax.jit, static_argnames=("block",))
def _quantize_jit(x, mids, block):
    n = x.shape[0]
    grid = n // block
    n_mids = mids.shape[0]
    return pl.pallas_call(
        _quantize_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((n_mids,), lambda i: (0,)),  # codebook midpoints
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=True,
    )(mids, x)


def quantize_blockwise(x, codebook: np.ndarray, block: int = BLOCK):
    """Pallas block-wise quantization; x length must be a block multiple
    (use ref.pad_to_blocks). Returns (codes u8, absmax f32 per block)."""
    x = jnp.asarray(x, jnp.float32)
    assert x.shape[0] % block == 0
    from . import codebooks

    mids = jnp.asarray(codebooks.midpoints(codebook))
    return _quantize_jit(x, mids, block)


@functools.partial(jax.jit, static_argnames=("block",))
def _dequantize_jit(codes, absmax, cb, block):
    n = codes.shape[0]
    grid = n // block
    n_cb = cb.shape[0]
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((n_cb,), lambda i: (0,)),  # codebook values
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(cb, codes, absmax)


def dequantize_blockwise(codes, absmax, codebook: np.ndarray, block: int = BLOCK):
    """Pallas block-wise dequantization."""
    cb = jnp.asarray(codebook)
    return _dequantize_jit(codes, absmax, cb, block)
