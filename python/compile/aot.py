"""AOT compiler: lower every L2 graph to HLO **text** + write the manifest.

HLO text (never ``.serialize()``) is the interchange format — the image's
xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id protos, while
the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs under --out-dir:
  {model}[_stable].{train,eval}.hlo.txt      one pair per model variant
  adam8_n{npad}.hlo.txt                      fused 8-bit Adam per tensor size
  momentum8_n{npad}.hlo.txt                  fused 8-bit Momentum per size
  quant_{signed,unsigned}_n{N}.hlo.txt       standalone kernels (parity tests)
  dequant_{signed,unsigned}_n{N}.hlo.txt
  manifest.json                              the Rust-side contract

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import optim8
from .kernels import codebooks
from .kernels.blockwise import BLOCK

DEFAULT_MODELS = "nano,nano:stable,tiny,tiny:stable,small,small:stable,cls_tiny,gpt100m:stable"

#: HLO optimizer-update artifacts are only generated for tensors up to this
#: many elements; larger tensors (e.g. gpt100m embeddings) use the native
#: Rust engine, which is the production hot path anyway (DESIGN.md §Perf).
MAX_HLO_UPDATE_SIZE = 4 << 20

#: Fixed sizes for the standalone kernel-parity artifacts.
PARITY_N = 8192


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer elides
    # >10-element literals as `constant({...})`, which the Rust-side HLO
    # text parser silently reads back as zeros — the 256-entry codebooks
    # baked into the kernels would vanish.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided literal in HLO text"
    return text


def lower_to_file(fn, example, path: str) -> None:
    lowered = jax.jit(fn).lower(*example)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)", flush=True)


def parse_model_arg(spec: str):
    if ":" in spec:
        preset, flag = spec.split(":")
        assert flag == "stable", spec
        return preset, True
    return spec, False


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=DEFAULT_MODELS,
                    help="comma list of presets, ':stable' suffix for the "
                         "stable-embedding graph variant")
    ap.add_argument("--block", type=int, default=BLOCK)
    ap.add_argument("--skip-updates", action="store_true",
                    help="skip per-size optimizer artifacts (fast dev builds)")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    manifest: dict = {
        "block": args.block,
        "codebooks": {
            name: [float(v) for v in codebooks.by_name(name)]
            for name in ("dynamic_signed", "dynamic_unsigned",
                         "linear_signed", "linear_unsigned")
        },
        "hp_layout": {
            "adam8": ["lr", "beta1", "beta2", "eps", "weight_decay",
                      "bias_c1", "bias_c2", "unused"],
            "momentum8": ["lr", "beta", "weight_decay", "is_first",
                          "unused", "unused", "unused", "unused"],
        },
        "models": [],
        "updates": {"adam8": {}, "momentum8": {}},
        "parity": {},
    }

    sizes: set[int] = set()
    for spec in args.models.split(","):
        preset, stable = parse_model_arg(spec.strip())
        cfg = model_lib.config_from(preset, stable)
        tag = f"{preset}_stable" if stable else preset
        print(f"model {tag}: {model_lib.n_params(cfg) / 1e6:.2f}M params", flush=True)

        train_fn, train_ex = model_lib.make_train_step(cfg)
        eval_fn, eval_ex = model_lib.make_eval_step(cfg)
        train_path = os.path.join(out, f"{tag}.train.hlo.txt")
        eval_path = os.path.join(out, f"{tag}.eval.hlo.txt")
        lower_to_file(train_fn, train_ex, train_path)
        lower_to_file(eval_fn, eval_ex, eval_path)

        params = []
        for s in model_lib.param_specs(cfg):
            size = math.prod(s.shape)
            npad = optim8.padded(size, args.block)
            if size <= MAX_HLO_UPDATE_SIZE:
                sizes.add(size)
            params.append({
                "name": s.name,
                "shape": list(s.shape),
                "init": s.init,
                "is_embedding": s.is_embedding,
                "size": size,
                "padded": npad,
            })
        manifest["models"].append({
            "name": tag,
            "preset": preset,
            "stable_embedding": stable,
            "task": cfg.task,
            "batch": cfg.batch,
            "seq_len": cfg.seq_len,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "n_classes": cfg.n_classes,
            "n_params": model_lib.n_params(cfg),
            "train": os.path.basename(train_path),
            "eval": os.path.basename(eval_path),
            "params": params,
        })

    if not args.skip_updates:
        for n in sorted(sizes):
            fn, ex = optim8.make_adam8_step(n, args.block)
            path = os.path.join(out, f"adam8_n{n}.hlo.txt")
            lower_to_file(fn, ex, path)
            manifest["updates"]["adam8"][str(n)] = os.path.basename(path)

            fn, ex = optim8.make_momentum8_step(n, args.block)
            path = os.path.join(out, f"momentum8_n{n}.hlo.txt")
            lower_to_file(fn, ex, path)
            manifest["updates"]["momentum8"][str(n)] = os.path.basename(path)

        # Standalone kernels for engine-parity tests.
        for signed in (True, False):
            name = "signed" if signed else "unsigned"
            fn, ex = optim8.make_quantize_graph(PARITY_N, signed, args.block)
            qpath = os.path.join(out, f"quant_{name}_n{PARITY_N}.hlo.txt")
            lower_to_file(fn, ex, qpath)
            fn, ex = optim8.make_dequantize_graph(PARITY_N, signed, args.block)
            dpath = os.path.join(out, f"dequant_{name}_n{PARITY_N}.hlo.txt")
            lower_to_file(fn, ex, dpath)
            manifest["parity"][f"quant_{name}"] = {
                "n": PARITY_N,
                "quant": os.path.basename(qpath),
                "dequant": os.path.basename(dpath),
            }

    mpath = os.path.join(out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
