"""L2 model tests: shapes, loss behaviour, stable-embedding variance,
gradient flow, and train-step graph contract."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M


def toy_cfg(**kw):
    import dataclasses
    base = M.PRESETS["nano"]
    return dataclasses.replace(base, **kw)


def tokens_for(cfg, seed=0, extra=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len + extra)).astype(np.int32))


def test_param_specs_sorted_and_unique():
    cfg = toy_cfg()
    specs = M.param_specs(cfg)
    names = [s.name for s in specs]
    assert names == sorted(names)
    assert len(set(names)) == len(names)


def test_param_count_scales_with_layers():
    a = M.n_params(toy_cfg(n_layers=2))
    b = M.n_params(toy_cfg(n_layers=4))
    assert b > a


def test_presets_param_counts():
    # gpt100m must satisfy the ~100M end-to-end mandate.
    n = M.n_params(M.PRESETS["gpt100m"])
    assert 90e6 < n < 130e6, n
    assert M.n_params(M.PRESETS["nano"]) < 1e6


def test_forward_shape():
    cfg = toy_cfg()
    p = M.init_params(cfg, seed=0)
    t = tokens_for(cfg, extra=0)
    h = M.forward(cfg, p, t)
    assert h.shape == (cfg.batch, cfg.seq_len, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))


def test_initial_lm_loss_close_to_uniform():
    cfg = toy_cfg()
    p = M.init_params(cfg, seed=0)
    loss = float(M.lm_loss(cfg, p, tokens_for(cfg)))
    assert abs(loss - math.log(cfg.vocab)) < 1.0, loss


def test_stable_embedding_unit_variance():
    # §2.3: the stable embedding maintains variance ≈ 1 at init.
    cfg = toy_cfg(stable_embedding=True)
    p = M.init_params(cfg, seed=0)
    t = tokens_for(cfg, extra=0)
    emb = M._embed(cfg, p, t)
    v = float(jnp.var(emb))
    assert 0.5 < v < 2.0, v


def test_standard_embedding_also_near_unit_variance():
    # fairseq recipe: N(0,1/√d) scaled by √d ⇒ variance ≈ 1 as well, but
    # built from a *normal* (heavier maxima) rather than uniform.
    cfg = toy_cfg(stable_embedding=False)
    p = M.init_params(cfg, seed=0)
    t = tokens_for(cfg, extra=0)
    emb = M._embed(cfg, p, t)
    v = float(jnp.var(emb))
    assert 0.5 < v < 2.0, v


def test_xavier_uniform_has_smaller_extremes_than_scaled_normal():
    # Appendix C: uniform init has less extreme values than normal.
    cfg_s = toy_cfg(stable_embedding=True)
    cfg_n = toy_cfg(stable_embedding=False)
    tok_s = M.init_params(cfg_s, seed=0)["embed.tok"]
    tok_n = M.init_params(cfg_n, seed=0)["embed.tok"] * math.sqrt(cfg_n.d_model)
    assert float(jnp.max(jnp.abs(tok_s))) < float(jnp.max(jnp.abs(tok_n)))


def test_grads_cover_all_params():
    cfg = toy_cfg()
    fn, example = M.make_train_step(cfg)
    p = M.init_params(cfg, seed=1)
    names = [s.name for s in M.param_specs(cfg)]
    out = fn(*[p[n] for n in names], tokens_for(cfg, seed=1))
    assert len(out) == 1 + len(names)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    nonzero = sum(1 for g in grads if float(jnp.max(jnp.abs(g))) > 0)
    assert nonzero == len(grads), f"{nonzero}/{len(grads)} grads non-zero"


def test_causal_masking():
    # Changing a future token must not change earlier-position logits.
    cfg = toy_cfg()
    p = M.init_params(cfg, seed=2)
    t = np.asarray(tokens_for(cfg, seed=3, extra=0)).copy()
    h1 = M.forward(cfg, p, jnp.asarray(t))
    t2 = t.copy()
    t2[:, -1] = (t2[:, -1] + 1) % cfg.vocab
    h2 = M.forward(cfg, p, jnp.asarray(t2))
    np.testing.assert_allclose(np.asarray(h1[:, :-1]), np.asarray(h2[:, :-1]),
                               rtol=1e-5, atol=1e-6)


def test_cls_task_outputs_loss_and_acc():
    cfg = M.PRESETS["cls_tiny"]
    p = M.init_params(cfg, seed=4)
    rng = np.random.default_rng(5)
    t = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32))
    y = jnp.asarray(rng.integers(0, cfg.n_classes, (cfg.batch,)).astype(np.int32))
    loss, acc = M.cls_loss(cfg, p, t, y)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0
    assert abs(float(loss) - math.log(cfg.n_classes)) < 0.5


def test_short_lm_training_reduces_loss():
    # Few steps of plain Adam on the python side: loss must drop. This is
    # the oracle the Rust trainer integration test mirrors.
    cfg = toy_cfg(batch=8)
    p = M.init_params(cfg, seed=6)
    names = [s.name for s in M.param_specs(cfg)]
    rng = np.random.default_rng(7)

    # learnable synthetic data: deterministic next-token structure
    def batch():
        start = rng.integers(0, cfg.vocab, (cfg.batch, 1))
        seq = [start]
        for _ in range(cfg.seq_len):
            seq.append((seq[-1] * 7 + 3) % cfg.vocab)
        return jnp.asarray(np.concatenate(seq, axis=1).astype(np.int32))

    loss_fn = jax.jit(lambda pp, tt: M.lm_loss(cfg, pp, tt))
    grad_fn = jax.jit(jax.value_and_grad(lambda pp, tt: M.lm_loss(cfg, pp, tt)))
    m = {n: jnp.zeros_like(p[n]) for n in names}
    v = {n: jnp.zeros_like(p[n]) for n in names}
    first = float(loss_fn(p, batch()))
    lr, b1, b2 = 1e-3, 0.9, 0.999
    for t in range(1, 31):
        loss, g = grad_fn(p, batch())
        for n in names:
            m[n] = b1 * m[n] + (1 - b1) * g[n]
            v[n] = b2 * v[n] + (1 - b2) * g[n] ** 2
            mh = m[n] / (1 - b1 ** t)
            vh = v[n] / (1 - b2 ** t)
            p[n] = p[n] - lr * mh / (jnp.sqrt(vh) + 1e-8)
    last = float(loss_fn(p, batch()))
    assert last < first - 0.3, f"{first} -> {last}"
