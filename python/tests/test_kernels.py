"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the kernel layer. Shapes/dtypes
are swept parametrically (hypothesis is unavailable in this offline image,
so the sweep is an explicit deterministic grid + seeded random draws —
same coverage intent).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import adam8bit, blockwise, codebooks, momentum8bit, ref


def rand(n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


CODEBOOKS = ["dynamic_signed", "dynamic_unsigned", "linear_signed", "linear_unsigned"]


# ---------------------------------------------------------------- codebooks
def test_codebook_sizes():
    assert len(codebooks.dynamic_signed()) == 256
    assert len(codebooks.dynamic_unsigned()) == 256
    assert len(codebooks.linear_signed()) == 255
    assert len(codebooks.linear_unsigned()) == 256


@pytest.mark.parametrize("name", CODEBOOKS)
def test_codebooks_sorted_distinct(name):
    cb = codebooks.by_name(name)
    assert np.all(np.diff(cb) > 0)
    assert cb.dtype == np.float32


def test_dynamic_signed_contains_anchors():
    cb = codebooks.dynamic_signed()
    for v in (1.0, -1.0, 0.0):
        assert v in cb


# ------------------------------------------------------- quantize vs oracle
@pytest.mark.parametrize("name", CODEBOOKS)
@pytest.mark.parametrize("n,block", [(2048, 2048), (8192, 2048), (4096, 1024), (256, 256)])
def test_pallas_quantize_matches_ref(name, n, block):
    cb = codebooks.by_name(name)
    x = rand(n, seed=n + block, scale=0.01)
    if "unsigned" in name:
        x = np.abs(x)
    ref_codes, ref_am = ref.quantize_blockwise(x, cb, block)
    pl_codes, pl_am = blockwise.quantize_blockwise(x, cb, block)
    np.testing.assert_array_equal(np.asarray(pl_codes), np.asarray(ref_codes))
    np.testing.assert_allclose(np.asarray(pl_am), np.asarray(ref_am), rtol=0)


@pytest.mark.parametrize("name", ["dynamic_signed", "dynamic_unsigned"])
def test_pallas_dequantize_matches_ref(name):
    cb = codebooks.by_name(name)
    n, block = 6144, 2048
    x = rand(n, seed=7, scale=0.3)
    if "unsigned" in name:
        x = np.abs(x)
    codes, am = ref.quantize_blockwise(x, cb, block)
    y_ref = ref.dequantize_blockwise(codes, am, cb, block)
    y_pl = blockwise.dequantize_blockwise(codes, am, cb, block)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref), rtol=0)


def test_roundtrip_exact_for_block_absmax():
    # §2.1: the per-block max quantizes with zero error.
    cb = codebooks.dynamic_signed()
    x = rand(4096, seed=9, scale=0.01)
    x[100] = 7.25
    x[3000] = -3.5
    codes, am = blockwise.quantize_blockwise(x, cb, 2048)
    y = np.asarray(blockwise.dequantize_blockwise(codes, am, cb, 2048))
    assert y[100] == np.float32(7.25)
    assert y[3000] == np.float32(-3.5)


def test_all_zero_block():
    cb = codebooks.dynamic_signed()
    x = np.zeros(2048, dtype=np.float32)
    codes, am = blockwise.quantize_blockwise(x, cb, 2048)
    y = np.asarray(blockwise.dequantize_blockwise(codes, am, cb, 2048))
    assert np.all(y == 0.0)


def test_pad_to_blocks():
    x = jnp.ones(1000, jnp.float32)
    y = ref.pad_to_blocks(x, 2048)
    assert y.shape[0] == 2048
    assert float(jnp.sum(y)) == 1000.0


# -------------------------------------------------------------- fused adam
@pytest.mark.parametrize("n,block", [(2048, 2048), (8192, 2048), (2048, 1024)])
@pytest.mark.parametrize("t", [1, 2, 10])
def test_adam8_kernel_matches_ref(n, block, t):
    cb1 = codebooks.dynamic_signed()
    cb2 = codebooks.dynamic_unsigned()
    p = rand(n, seed=1)
    g = rand(n, seed=2, scale=0.1)
    m0 = rand(n, seed=3, scale=0.01)
    r0 = np.abs(rand(n, seed=4, scale=1e-4))
    c1, a1 = ref.quantize_blockwise(m0, cb1, block)
    c2, a2 = ref.quantize_blockwise(r0, cb2, block)
    hp = adam8bit.make_hp(lr=1e-3, beta1=0.9, beta2=0.995, eps=1e-7,
                          weight_decay=0.01, t=t)
    upd = adam8bit.build_adam8_update(n, block)
    p_k, c1_k, a1_k, c2_k, a2_k = upd(hp, p, g, c1, a1, c2, a2)
    p_r, c1_r, a1_r, c2_r, a2_r = ref.adam8bit_update(
        p, g, c1, a1, c2, a2, cb1, cb2, block,
        lr=1e-3, beta1=0.9, beta2=0.995, eps=1e-7, weight_decay=0.01, t=t)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r), rtol=1e-6, atol=1e-7)
    # codes may differ only on exact decision-boundary ties; require equality
    np.testing.assert_array_equal(np.asarray(c1_k), np.asarray(c1_r))
    np.testing.assert_array_equal(np.asarray(c2_k), np.asarray(c2_r))
    np.testing.assert_allclose(np.asarray(a1_k), np.asarray(a1_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a2_k), np.asarray(a2_r), rtol=1e-6)


def test_adam8_converges_on_quadratic():
    # End-to-end sanity: the fused kernel actually optimizes.
    n, block = 2048, 2048
    cb1 = codebooks.dynamic_signed()
    cb2 = codebooks.dynamic_unsigned()
    target = rand(n, seed=11)
    p = np.zeros(n, dtype=np.float32)
    c1, a1 = ref.quantize_blockwise(np.zeros(n, np.float32), cb1, block)
    c2, a2 = ref.quantize_blockwise(np.zeros(n, np.float32), cb2, block)
    upd = adam8bit.build_adam8_update(n, block)
    for t in range(1, 151):
        g = (p - target).astype(np.float32)
        hp = adam8bit.make_hp(0.05, 0.9, 0.995, 1e-7, 0.0, t)
        p, c1, a1, c2, a2 = (np.asarray(v) for v in upd(hp, p, g, c1, a1, c2, a2))
    mse = float(np.mean((p - target) ** 2))
    assert mse < 5e-3, mse


# ---------------------------------------------------------- fused momentum
@pytest.mark.parametrize("t", [1, 2, 5])
def test_momentum8_kernel_matches_ref(t):
    n, block = 4096, 2048
    cb = codebooks.dynamic_signed()
    p = rand(n, seed=21)
    g = rand(n, seed=22, scale=0.1)
    m0 = rand(n, seed=23, scale=0.05)
    c, a = ref.quantize_blockwise(m0, cb, block)
    hp = momentum8bit.make_hp(lr=0.1, beta=0.9, weight_decay=0.0, t=t)
    upd = momentum8bit.build_momentum8_update(n, block)
    p_k, c_k, a_k = upd(hp, p, g, c, a)
    p_r, c_r, a_r = ref.momentum8bit_update(p, g, c, a, cb, block,
                                            lr=0.1, beta=0.9, weight_decay=0.0, t=t)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r), rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r), rtol=1e-6)
